"""The Planner API: configure() shim bit-exactness, Plan JSON round-trip,
byte-identical determinism per strategy, and Plan-driven mesh construction
(the acceptance criteria of the api_redesign issue)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core import (MID_RANGE, AMPStrategy, Budget, Conf,
                        ExhaustiveStrategy, MegatronStrategy, Plan, Planner,
                        PlanRequest, PipetteStrategy, SearchSpace, Strategy,
                        VarunaStrategy, Workload, configure,
                        fit_memory_estimator, profile_bandwidth,
                        true_bandwidth_matrix)
from repro.configs.gpt_paper import GPT_3_1B
from repro.models.config import ModelConfig

SRC = str(Path(__file__).resolve().parent.parent / "src")

GPT = ModelConfig(name="g", family="dense", n_layers=16, d_model=1024,
                  n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=32000)
SPEC = MID_RANGE.with_nodes(1)                  # 8 GPUs: fast, full coverage
W = Workload(GPT, 2048, 32)

# iteration-bound SA budget: deterministic trajectories, tiny runtime
BUDGET = Budget(sa_seconds=60.0, sa_iters=80, sa_topk=4)
REQ = PlanRequest(workload=W, spec=SPEC,
                  space=SearchSpace(max_micro=4), budget=BUDGET, seed=7)


@pytest.fixture(scope="module")
def bw():
    return profile_bandwidth(SPEC)[0]


# ---------------------------------------------------------------------------
# configure() is a bit-exact shim over Planner(PipetteStrategy())
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("space_kw", [{}, {"max_cp": 2}],
                         ids=["3d", "4d_max_cp2"])
def test_configure_shim_bit_exact_midrange(space_kw):
    """Acceptance: on MID_RANGE (16 nodes / 128 GPUs), 3D and a max_cp=2 4D
    search, the legacy kwarg shim and the Planner entry point return the
    same best conf, the same mapping, the same latency — and the same full
    ranking."""
    spec = MID_RANGE
    w = Workload(GPT_3_1B, 2048, 256)
    bw_meas, _ = profile_bandwidth(spec)
    kw = dict(sa_seconds=60.0, sa_iters=60, sa_topk=4, max_micro=4, seed=3)
    res = configure(w, spec, bw_meas, **kw, **space_kw)
    req = PlanRequest(
        workload=w, spec=spec,
        space=SearchSpace(max_micro=4, **space_kw),
        budget=Budget(sa_seconds=60.0, sa_iters=60, sa_topk=4), seed=3)
    plan = Planner(PipetteStrategy()).plan(req, bw_meas)

    assert plan.conf == res.best.conf
    assert plan.latency == res.best.latency
    assert np.array_equal(plan.mapping, res.best.mapping)
    assert plan.mapping.dtype == res.best.mapping.dtype
    # full in-process ranking, not just the winner
    assert [c.conf for c in plan.result.ranked] == \
        [c.conf for c in res.ranked]
    assert [c.latency for c in plan.result.ranked] == \
        [c.latency for c in res.ranked]
    if space_kw.get("max_cp", 1) > 1:
        assert any(c.conf.cp > 1 for c in res.ranked)


def test_configure_dedicate_false_is_exhaustive_strategy(bw):
    res = configure(W, SPEC, bw, dedicate=False, max_micro=4, seed=7)
    plan = Planner(ExhaustiveStrategy()).plan(
        PlanRequest(workload=W, spec=SPEC, space=SearchSpace(max_micro=4),
                    seed=7), bw)
    assert plan.conf == res.best.conf
    assert plan.latency == res.best.latency
    assert np.array_equal(plan.mapping, res.best.mapping)


# ---------------------------------------------------------------------------
# all strategies behind the one interface
# ---------------------------------------------------------------------------

def _strategies(bw):
    return [PipetteStrategy(), ExhaustiveStrategy(), AMPStrategy(),
            VarunaStrategy(),
            MegatronStrategy(trials=3, bw_true=true_bandwidth_matrix(SPEC))]


def test_every_strategy_satisfies_protocol_and_plans(bw):
    for strat in _strategies(bw):
        assert isinstance(strat, Strategy)
        plan = Planner(strat).plan(REQ, bw)
        assert plan.provenance.strategy == strat.name
        assert plan.feasible
        assert plan.conf.n_gpus == SPEC.n_gpus
        assert sorted(np.asarray(plan.mapping).reshape(-1).tolist()) == \
            list(range(SPEC.n_gpus))
        assert plan.ranked[0].conf == plan.conf
        assert [c.latency for c in plan.ranked] == \
            sorted(c.latency for c in plan.ranked)
        # baselines stay 3D by design
        if strat.name in ("amp", "varuna", "megatron-lm"):
            assert all(c.conf.cp == 1 for c in plan.ranked)


def test_strategy_names_are_distinct(bw):
    names = [s.name for s in _strategies(bw)]
    assert len(set(names)) == len(names)


# ---------------------------------------------------------------------------
# Plan JSON round-trip
# ---------------------------------------------------------------------------

def test_plan_roundtrip_preserves_everything(tmp_path, bw):
    plan = Planner(PipetteStrategy()).plan(REQ, bw)
    p = tmp_path / "plan.json"
    plan.save(p)
    back = Plan.load(p)

    assert back.conf == plan.conf
    assert back.latency == plan.latency
    assert np.array_equal(back.mapping, plan.mapping)
    assert back.mapping.dtype == plan.mapping.dtype      # dtype preserved
    assert back.mapping.shape == plan.mapping.shape      # shape preserved
    assert len(back.ranked) == len(plan.ranked)
    for a, b in zip(back.ranked, plan.ranked):
        assert a.conf == b.conf and a.latency == b.latency
        assert np.array_equal(a.mapping, b.mapping)
        assert a.mapping.dtype == b.mapping.dtype
        # NaN mem_pred (no estimator) must survive the null round trip
        assert (a.mem_pred == b.mem_pred or
                (np.isnan(a.mem_pred) and np.isnan(b.mem_pred)))
    pv, qv = back.provenance, plan.provenance
    assert (pv.strategy, pv.seed, pv.bw_digest) == \
        (qv.strategy, qv.seed, qv.bw_digest)
    assert pv.space == qv.space and pv.budget == qv.budget
    assert back.overhead.n_candidates == plan.overhead.n_candidates
    assert back.overhead.n_enumerated == plan.overhead.n_enumerated
    # the in-process search result is deliberately not serialized
    assert plan.result is not None and back.result is None
    # re-serializing the loaded plan is byte-identical (fixed point)
    assert back.to_json() == plan.to_json()


def test_plan_roundtrip_4d_mapping(tmp_path, bw):
    """cp>1 mappings are 4D (pp, tp, cp, dp); the JSON round trip must
    bring the rank-4 shape back exactly."""
    req = PlanRequest(workload=W, spec=SPEC,
                      space=SearchSpace(max_micro=4, max_cp=2),
                      budget=BUDGET, seed=7)
    plan = Planner(PipetteStrategy()).plan(req, bw, keep_top=50)
    four_d = [c for c in plan.ranked if c.conf.cp > 1]
    assert four_d, "4D search produced no cp>1 candidates in the top-k"
    p = tmp_path / "plan4d.json"
    plan.save(p)
    back = Plan.load(p)
    for a, b in zip(back.ranked, plan.ranked):
        assert a.mapping.shape == b.mapping.shape
        assert np.array_equal(a.mapping, b.mapping)
    four_d_back = [c for c in back.ranked if c.conf.cp > 1]
    assert four_d_back[0].mapping.ndim == 4
    assert four_d_back[0].mapping.shape == four_d[0].mapping.shape


def test_plan_estimator_provenance(tmp_path, bw):
    est = fit_memory_estimator([W], SPEC, fit_nodes=1, steps=300,
                               residual=True)
    plan = Planner(PipetteStrategy(estimator=est)).plan(REQ, bw)
    e = plan.provenance.estimator
    assert e is not None
    assert e["residual"] is True and e["with_cp"] is False
    assert e["fit_gpu_mem"] == SPEC.gpu_mem
    assert e["fit_gpus_per_node"] == SPEC.gpus_per_node
    p = tmp_path / "plan.json"
    plan.save(p)
    assert Plan.load(p).provenance.estimator == e
    # memory predictions came through the estimator, not NaN
    assert np.isfinite(plan.mem_pred)


def test_infeasible_plan_roundtrip_and_mesh_refusal(tmp_path, bw):
    """Every candidate pruned -> a feasible=False Plan that still
    serializes (recording the outcome) and that the launch layer refuses
    to build a mesh from."""
    est = fit_memory_estimator([W], SPEC, fit_nodes=1, steps=300,
                               residual=True)
    plan = Planner(PipetteStrategy(estimator=est, mem_limit=1.0)).plan(
        REQ, bw)
    assert not plan.feasible
    assert plan.conf is None and plan.mapping is None
    assert plan.latency == float("inf")
    p = tmp_path / "infeasible.json"
    plan.save(p)
    back = Plan.load(p)
    assert not back.feasible and back.ranked == ()
    from repro.launch.mesh import mesh_from_plan
    with pytest.raises(ValueError, match="infeasible"):
        mesh_from_plan(back)


def test_plan_rejects_unknown_schema_version(tmp_path, bw):
    plan = Planner(AMPStrategy()).plan(REQ, bw)
    d = plan.to_json_dict()
    d["version"] = 99
    p = tmp_path / "future.json"
    p.write_text(json.dumps(d))
    with pytest.raises(ValueError, match="schema version"):
        Plan.load(p)


# ---------------------------------------------------------------------------
# determinism: same request + seed -> byte-identical JSON, every strategy
# ---------------------------------------------------------------------------

def test_plan_json_byte_identical_across_runs(tmp_path, bw):
    for strat in _strategies(bw):
        a = Planner(strat).plan(REQ, bw).save(tmp_path / "a.json")
        b = Planner(strat).plan(REQ, bw).save(tmp_path / "b.json")
        assert Path(a).read_bytes() == Path(b).read_bytes(), strat.name


def test_bw_digest_tracks_the_matrix(bw):
    plan_a = Planner(AMPStrategy()).plan(REQ, bw)
    plan_b = Planner(AMPStrategy()).plan(REQ, bw + 1.0)
    assert plan_a.provenance.bw_digest != plan_b.provenance.bw_digest


def test_megatron_digest_fingerprints_the_scoring_matrix(bw):
    """MegatronStrategy(bw_true=...) runs its trials on bw_true, ignoring
    the profiled bw — provenance must fingerprint the matrix the latencies
    actually came from, else the staleness check validates noise."""
    from repro.core import bw_fingerprint
    bw_true = true_bandwidth_matrix(SPEC)
    plan = Planner(MegatronStrategy(trials=3, bw_true=bw_true)).plan(REQ, bw)
    assert plan.provenance.bw_digest == bw_fingerprint(bw_true)
    assert plan.provenance.bw_digest != bw_fingerprint(bw)
    # without a bw_true override the handed-in matrix is the scoring one
    plan2 = Planner(MegatronStrategy(trials=3)).plan(REQ, bw)
    assert plan2.provenance.bw_digest == bw_fingerprint(bw)


# ---------------------------------------------------------------------------
# a saved Plan drives mesh construction without re-running the search
# ---------------------------------------------------------------------------

def test_cli_plan_reloads_and_drives_mesh(tmp_path):
    """Acceptance: `python -m repro.plan plan` writes the artifact; a fresh
    process (8 forced host devices, no search) loads it and builds the
    Mesh straight from the mapping."""
    out = tmp_path / "plan.json"
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.plan", "plan",
         "--config", "qwen2-7b", "--reduced", "--cluster", "mid-range",
         "--nodes", "1", "--seq", "128", "--bs-global", "16",
         "--sa-iters", "100", "-o", str(out)],
        capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert out.exists()

    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    code = f"""
        import numpy as np
        from repro.core import Plan
        from repro.launch.mesh import mesh_from_plan
        plan = Plan.load({str(out)!r})
        mesh = mesh_from_plan(plan)
        assert mesh.devices.shape == plan.mapping.shape
        assert mesh.axis_names[:2] == ("pipe", "model")
        want = np.asarray(plan.mapping).reshape(-1).tolist()
        got = [d.id for d in mesh.devices.reshape(-1)]
        assert got == want, (got, want)
        print("MESH_OK", mesh.devices.shape)
    """
    r2 = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                        capture_output=True, text=True, timeout=600, env=env)
    assert r2.returncode == 0, f"stdout:\n{r2.stdout}\nstderr:\n{r2.stderr}"
    assert "MESH_OK" in r2.stdout


# ---------------------------------------------------------------------------
# runtime consumption: TrainLoop persists plan provenance; replan emits one
# ---------------------------------------------------------------------------

def test_trainloop_persists_plan_json(tmp_path, bw):
    import jax
    import jax.numpy as jnp
    from repro.data.pipeline import DataLoader, LoaderConfig, SyntheticCorpus
    from repro.optim.adamw import AdamW
    from repro.runtime.trainer import TrainLoop, TrainLoopConfig

    plan = Planner(PipetteStrategy()).plan(REQ, bw)
    opt = AdamW(lr=0.05, weight_decay=0.0)

    @jax.jit
    def step(params, opt_state, batch):
        x = jnp.asarray(batch["tokens"], jnp.float32) / 10.0
        y = jnp.asarray(batch["labels"], jnp.float32) / 10.0
        loss, g = jax.value_and_grad(
            lambda p: jnp.mean((x @ p["w"] - y) ** 2))(params)
        params, opt_state = opt.update(g, opt_state, params)
        return params, opt_state, {"loss": loss}

    loader = DataLoader(SyntheticCorpus(vocab_size=9, seed=1),
                        LoaderConfig(4, 8))
    cfg = TrainLoopConfig(total_steps=3, ckpt_every=3,
                          ckpt_dir=str(tmp_path / "run"))
    params = {"w": jnp.zeros((8, 8))}
    loop = TrainLoop(cfg, step, loader, plan=plan)
    loop.run(params, opt.init(params), resume=False)

    saved = Plan.load(loop.plan_path())
    assert saved.conf == plan.conf
    assert np.array_equal(saved.mapping, plan.mapping)
    assert saved.provenance.bw_digest == plan.provenance.bw_digest


def test_replan_returns_plan_artifact(tmp_path):
    from repro.runtime.elastic import replan
    ep = replan(W, SPEC.with_nodes(4), healthy_nodes=3, sa_seconds=0.1,
                sa_topk=2)
    assert ep.plan is not None and ep.plan.feasible
    assert ep.plan.conf.n_gpus == 24
    assert ep.plan.provenance.strategy == "pipette"
    assert ep.result is ep.plan.result      # full ranking still exposed
    p = tmp_path / "replan.json"
    ep.plan.save(p)
    assert Plan.load(p).conf == ep.plan.conf


def test_replan_rejects_unknown_kwargs():
    from repro.runtime.elastic import replan
    with pytest.raises(TypeError, match="unknown replan"):
        replan(W, SPEC, healthy_nodes=1, not_a_knob=3)


# ---------------------------------------------------------------------------
# request validation
# ---------------------------------------------------------------------------

def test_request_dataclasses_validate_and_freeze():
    with pytest.raises(ValueError):
        SearchSpace(max_cp=0)
    with pytest.raises(ValueError):
        Budget(sa_iters=0)
    req = PlanRequest(workload=W, spec=SPEC)
    with pytest.raises(Exception):          # frozen
        req.seed = 1
    assert req.space == SearchSpace() and req.budget == Budget()


def test_conf_roundtrip_via_plan_schema():
    from repro.core.plan import _conf_in, _conf_out
    for conf in (Conf(2, 2, 2, 2, 64), Conf(2, 2, 1, 2, 32, cp=2),
                 Conf(1, 8, 1, 4, 32)):
        assert _conf_in(_conf_out(conf)) == conf
