"""Property suite for the SA scoring engines.

Three properties over *random* cluster specs (uniform / mixed-tier /
degraded-host) and random move sequences:

1. delta-scoring soundness — ``DedicationEngine.propose`` (the cached
   incremental path) returns bit-exactly the value a fresh full
   ``score`` of the moved permutation would, move after move;
2. backend equivalence — the JAX engine scores the same trajectory
   bit-identically to the NumPy engine (the pinned tolerance is *zero*
   on CPU, where FMA contraction is disabled at compile time; rel 1e-12
   elsewhere);
3. reference fidelity — both agree with the pure-Python
   ``pipette_latency_ref`` within rel 1e-12 (the scalar reference
   associates differently, so bitwise equality is not expected).

Every property runs twice: as a seeded exhaustive sweep (always on — the
CI baseline) and as a Hypothesis fuzz (skipped when hypothesis is not
installed) that searches a much wider spec/move space for violations."""
import numpy as np
import pytest

from repro.core import (ClusterSpec, Conf, DedicationEngine, Workload,
                        build_profile, make_move_plan, perm_to_mapping,
                        pipette_latency_ref, profile_bandwidth)
from repro.core.annealing import _move_numpy
from repro.core.cluster import (A100_TIER, V100_TIER,
                                degraded_host_spec, mixed_fleet_spec)
from repro.configs.gpt_paper import GPT_3_1B

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

requires_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed")


# ---------------------------------------------------------------------------
# random spec / conf generation (shared by both harnesses)
# ---------------------------------------------------------------------------

def _make_spec(kind: str, n_nodes: int, gpn: int, seed: int) -> ClusterSpec:
    name = f"prop-{kind}-{n_nodes}x{gpn}-{seed}"
    if kind == "uniform":
        return ClusterSpec(name, n_nodes, gpus_per_node=gpn, seed=seed)
    if kind == "mixed":
        return mixed_fleet_spec(name, n_nodes,
                                (A100_TIER, V100_TIER),
                                gpus_per_node=gpn, seed=seed)
    if kind == "degraded":
        base = ClusterSpec(name, n_nodes, gpus_per_node=gpn, seed=seed)
        return degraded_host_spec(base, degraded_frac=0.3, seed=seed)
    raise AssertionError(kind)


def _make_conf(n: int, seed: int) -> Conf:
    """A random valid 4D factorization of ``n`` GPUs (cp kept <= 2)."""
    rng = np.random.default_rng(seed)

    def divisors(m):
        return [d for d in range(1, m + 1) if m % d == 0]

    pp = int(rng.choice(divisors(n)))
    tp = int(rng.choice(divisors(n // pp)))
    cp = int(rng.choice([c for c in divisors(n // (pp * tp)) if c <= 2]))
    dp = n // (pp * tp * cp)
    n_mb = int(rng.choice([1, 2, 4]))
    return Conf(pp, tp, dp, 1, dp * n_mb, cp)


def _random_walk(spec, conf, seed, n_moves, check_jax):
    """Walk ``n_moves`` random moves checking all three properties."""
    bw, _ = profile_bandwidth(spec)
    W = Workload(GPT_3_1B, 2048, conf.bs_global)
    prof = build_profile(W, spec, conf)
    eng = DedicationEngine(conf, bw, prof, spec)
    fresh = DedicationEngine(conf, bw, prof, spec)
    jeng = None
    if check_jax:
        from repro.core.jax_engine import JaxDedicationEngine
        jeng = JaxDedicationEngine([conf], [prof], bw, spec)

    rng = np.random.default_rng(seed)
    n = conf.n_gpus
    perm = rng.permutation(n)
    cur = eng.score(perm)
    for _ in range(n_moves):
        kind = int(rng.integers(3))
        pa = int(rng.integers(n))
        pb = int(rng.integers(n - 1))
        pb += pb >= pa
        cand, touched = _move_numpy(perm, kind, pa, pb)
        val, pending = eng.propose(cand, touched)
        # 1. incremental == full re-score, bitwise
        assert float(val).hex() == float(fresh.score(cand)).hex(), \
            (spec.name, conf, kind, pa, pb)
        if jeng is not None:
            # 2. JAX backend parity (bit-exact on CPU, see module doc)
            got = jeng.score(cand)
            import jax
            if jax.default_backend() == "cpu":
                assert float(got).hex() == float(val).hex()
            else:
                assert got == pytest.approx(val, rel=1e-12)
        # 3. the scalar reference agrees to 1e-12
        ref = pipette_latency_ref(conf, perm_to_mapping(cand, conf), bw,
                                  prof, spec)
        assert val == pytest.approx(ref, rel=1e-12)
        if val < cur:                # greedy walk keeps states diverse
            eng.commit(pending)
            perm, cur = cand, val


# ---------------------------------------------------------------------------
# seeded sweep (always on)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["uniform", "mixed", "degraded"])
@pytest.mark.parametrize("n_nodes,gpn", [(4, 2), (3, 4), (8, 2)])
def test_property_walk_seeded(kind, n_nodes, gpn):
    pytest.importorskip("jax")
    seed = n_nodes * 101 + gpn
    spec = _make_spec(kind, n_nodes, gpn, seed)
    conf = _make_conf(spec.n_gpus, seed + 1)
    _random_walk(spec, conf, seed + 2, n_moves=12, check_jax=True)


def test_numpy_walk_without_jax():
    """The NumPy-only properties hold regardless of jax availability."""
    spec = _make_spec("mixed", 6, 2, 77)
    conf = _make_conf(spec.n_gpus, 78)
    _random_walk(spec, conf, 79, n_moves=10, check_jax=False)


def test_move_plan_thresholds_reproduce_log_draws():
    """The precomputed accept thresholds are exactly ``-log(u)`` of the
    per-chain RNG stream — the device-side accept rule
    ``delta < temp * thresh`` is the host rule ``u < exp(-delta/temp)``."""
    plan = make_move_plan([12], 40, 2, seed=5)
    for k in range(2):
        rng = np.random.default_rng(5 * 100003 + k)
        # replay the draw order: probes first, then iteration draws
        rng.integers(3, size=plan.n_probes)
        rng.integers(12, size=plan.n_probes)
        rng.integers(11, size=plan.n_probes)
        t = plan.kind.shape[1]
        rng.integers(3, size=t)
        rng.integers(12, size=t)
        rng.integers(11, size=t)
        u = rng.random(t)
        assert np.array_equal(plan.thresh[k], -np.log(u))


# ---------------------------------------------------------------------------
# hypothesis fuzz (wider space; skipped when the package is absent)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    spec_kinds = st.sampled_from(["uniform", "mixed", "degraded"])
    node_counts = st.integers(min_value=2, max_value=8)
    gpns = st.sampled_from([1, 2, 4])
    seeds = st.integers(min_value=0, max_value=2**31 - 1)

    @requires_hypothesis
    @settings(max_examples=25, deadline=None)
    @given(kind=spec_kinds, n_nodes=node_counts, gpn=gpns, seed=seeds)
    def test_property_walk_fuzzed(kind, n_nodes, gpn, seed):
        pytest.importorskip("jax")
        spec = _make_spec(kind, n_nodes, gpn, seed % 10_000)
        conf = _make_conf(spec.n_gpus, seed + 1)
        _random_walk(spec, conf, seed + 2, n_moves=6, check_jax=True)

    @requires_hypothesis
    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(min_value=4, max_value=64), seed=seeds)
    def test_move_semantics_fuzzed(n, seed):
        """_move_numpy always yields a permutation and touched covers
        every changed position."""
        rng = np.random.default_rng(seed)
        perm = rng.permutation(n)
        kind = int(rng.integers(3))
        pa = int(rng.integers(n))
        pb = int(rng.integers(n - 1))
        pb += pb >= pa
        moved, touched = _move_numpy(perm, kind, pa, pb)
        assert np.array_equal(np.sort(moved), np.arange(n))
        changed = np.nonzero(moved != perm)[0]
        assert set(changed.tolist()) <= set(np.asarray(touched).tolist())
