"""Checkpointing, data pipeline, fault tolerance, straggler watchdog,
elastic re-plan, optimizer, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataLoader, LoaderConfig, SyntheticCorpus
from repro.optim.adamw import AdamW, cosine_schedule
from repro.optim.compression import PowerSGD
from repro.runtime.trainer import StragglerWatchdog, TrainLoop, TrainLoopConfig
from repro.runtime.elastic import replan
from repro.core import MID_RANGE, Workload
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree(key):
    ks = jax.random.split(key, 3)
    return {"a": jax.random.normal(ks[0], (4, 8)),
            "nested": {"b": jax.random.normal(ks[1], (3,)),
                       "c": jnp.ones((2, 2), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    t = _tree(jax.random.PRNGKey(0))
    mgr.save(10, t)
    restored, step = mgr.restore(t)
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    t = _tree(jax.random.PRNGKey(1))
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert sorted(mgr.steps()) == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_detects_topology_mismatch(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(1, {"a": jnp.ones((2,))})
    with pytest.raises(ValueError):
        mgr.restore({"a": jnp.ones((2,)), "b": jnp.ones((2,))})


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_data_deterministic_and_sharded():
    corpus = SyntheticCorpus(vocab_size=97, seed=3)
    full = DataLoader(corpus, LoaderConfig(8, 32))
    r0 = DataLoader(corpus, LoaderConfig(8, 32, dp_rank=0, dp_size=2))
    r1 = DataLoader(corpus, LoaderConfig(8, 32, dp_rank=1, dp_size=2))
    b_full = full.batch_at(5)
    b0, b1 = r0.batch_at(5), r1.batch_at(5)
    np.testing.assert_array_equal(
        np.concatenate([b0["tokens"], b1["tokens"]]), b_full["tokens"])
    np.testing.assert_array_equal(full.batch_at(5)["tokens"],
                                  b_full["tokens"])  # reproducible
    assert b_full["labels"][0, 0] == b_full["tokens"][0, 1]  # shifted


def test_data_prefetch_iterator():
    corpus = SyntheticCorpus(vocab_size=31, seed=0)
    dl = DataLoader(corpus, LoaderConfig(2, 8))
    batches = list(dl.iterate(start_step=3, stop_step=6))
    assert len(batches) == 3
    np.testing.assert_array_equal(batches[0]["tokens"],
                                  dl.batch_at(3)["tokens"])


# ---------------------------------------------------------------------------
# fault tolerance / straggler / elastic
# ---------------------------------------------------------------------------

def _toy_step_fn():
    opt = AdamW(lr=0.05, weight_decay=0.0)

    @jax.jit
    def step(params, opt_state, batch):
        x = jnp.asarray(batch["tokens"], jnp.float32) / 10.0
        y = jnp.asarray(batch["labels"], jnp.float32) / 10.0

        def loss_fn(p):
            pred = x @ p["w"]
            return jnp.mean((pred - y) ** 2)

        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(g, opt_state, params)
        return params, opt_state, {"loss": loss}

    return opt, step


def test_trainloop_failure_recovery_bitwise(tmp_path):
    """Crash at step 7, restart, final params equal the no-crash run."""
    corpus = SyntheticCorpus(vocab_size=9, seed=1)
    loader = DataLoader(corpus, LoaderConfig(4, 8))

    def fresh():
        opt, step = _toy_step_fn()
        params = {"w": jnp.zeros((8, 8))}
        return step, params, opt.init(params)

    cfg = TrainLoopConfig(total_steps=12, ckpt_every=5,
                          ckpt_dir=str(tmp_path / "a"))
    step_fn, params, opt_state = fresh()
    loop = TrainLoop(cfg, step_fn, loader)
    p_ref, _ = loop.run(params, opt_state, resume=False)

    cfg2 = TrainLoopConfig(total_steps=12, ckpt_every=5,
                           ckpt_dir=str(tmp_path / "b"))
    step_fn, params, opt_state = fresh()
    crash = TrainLoop(cfg2, step_fn, loader, fail_at_step=7)
    with pytest.raises(RuntimeError, match="injected failure"):
        crash.run(params, opt_state, resume=False)
    # restart: auto-resume from step 5 checkpoint
    step_fn, params, opt_state = fresh()
    resume = TrainLoop(cfg2, step_fn, loader)
    p_rec, _ = resume.run(params, opt_state, resume=True)
    np.testing.assert_array_equal(np.asarray(p_ref["w"]),
                                  np.asarray(p_rec["w"]))


def test_straggler_watchdog_fires():
    fired = []
    wd = StragglerWatchdog(threshold=1.5, warmup_steps=3,
                           on_straggler=lambda s, dt, e: fired.append(s))
    for s in range(10):
        wd.observe(s, 0.1)
    assert not fired
    wd.observe(10, 0.5)
    assert fired == [10]
    # EWMA is not polluted by the straggler observation
    assert wd.observe(11, 0.1) is False


def test_elastic_replan_degraded_cluster():
    cfg = ModelConfig(name="g", family="dense", n_layers=16, d_model=1024,
                      n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=32000)
    w = Workload(cfg, 1024, 64)
    plan = replan(w, MID_RANGE.with_nodes(4), healthy_nodes=3,
                  sa_seconds=0.1)
    best = plan.result.best
    assert best.conf.n_gpus == 3 * 8
    m = best.mapping.reshape(-1)
    assert sorted(m.tolist()) == list(range(24))


def test_elastic_replan_16_to_12_nodes_keeps_matching_estimator():
    """Regression (ISSUE 3): a 16 -> 12 node shrink keeps gpu_mem and
    gpus_per_node, so the estimator fit on the original spec stays valid
    and must NOT be refit."""
    from repro.core import fit_memory_estimator

    cfg = ModelConfig(name="g", family="dense", n_layers=16, d_model=1024,
                      n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=32000)
    w = Workload(cfg, 1024, 64)
    spec = MID_RANGE.with_nodes(16)
    est = fit_memory_estimator([w], spec, fit_nodes=2, steps=1500,
                               residual=True)
    assert est.fit_gpu_mem == spec.gpu_mem
    plan = replan(w, spec, healthy_nodes=12, estimator=est,
                  sa_seconds=0.05, sa_topk=2)
    assert not plan.refit_estimator
    assert plan.n_gpus == 12 * 8
    assert plan.result.best.conf.n_gpus == 96


def test_elastic_replan_refits_estimator_on_changed_hardware():
    """When the replacement nodes have different per-GPU memory, the old
    fit is invalid for the new ground truth: replan must refit instead of
    silently reusing it."""
    import dataclasses

    from repro.core import fit_memory_estimator

    cfg = ModelConfig(name="g", family="dense", n_layers=16, d_model=1024,
                      n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=32000)
    w = Workload(cfg, 1024, 64)
    spec = MID_RANGE.with_nodes(4)
    est = fit_memory_estimator([w], spec, fit_nodes=1, steps=600,
                               residual=True)
    shrunk = dataclasses.replace(spec, gpu_mem=spec.gpu_mem / 2)
    plan = replan(w, shrunk, healthy_nodes=3, estimator=est,
                  sa_seconds=0.05, sa_topk=2, refit_steps=600)
    assert plan.refit_estimator
    assert plan.result.best is not None
    assert plan.result.best.conf.n_gpus == 24


def test_elastic_replan_refits_3d_estimator_for_4d_search():
    """A 3D-fit estimator cannot score cp>1 candidates; replan(max_cp>1)
    must refit (cp-aware) instead of crashing in predict_batch."""
    from repro.core import fit_memory_estimator

    cfg = ModelConfig(name="g", family="dense", n_layers=16, d_model=1024,
                      n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=32000)
    w = Workload(cfg, 1024, 64)
    spec = MID_RANGE.with_nodes(4)
    est = fit_memory_estimator([w], spec, fit_nodes=1, steps=600,
                               residual=True)
    assert not est.with_cp
    plan = replan(w, spec, healthy_nodes=3, estimator=est,
                  sa_seconds=0.05, sa_topk=2, refit_steps=600, max_cp=2)
    assert plan.refit_estimator
    assert plan.result.best is not None
    assert any(c.conf.cp > 1 for c in plan.result.ranked)


def _tiny_workload():
    cfg = ModelConfig(name="g", family="dense", n_layers=16, d_model=1024,
                      n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=32000)
    return Workload(cfg, 1024, 64)


@pytest.mark.parametrize("kw", [
    {"partition": "dp"},
    {"max_vpp": 2},
    {"backend": "numpy"},
    {"backend": "jax"},
    {"hierarchical": False},
    {"warm_start": tuple(range(24))},
], ids=lambda kw: next(iter(kw)))
def test_replan_routes_every_new_request_knob(kw):
    """Regression (ISSUE 10): the replan() kwarg split is derived from the
    SearchSpace/Budget dataclass fields, so every knob added since the
    original hardcoded allowlists must route — passing any of these used
    to raise ``TypeError: unknown replan() keywords``."""
    from repro.core.plan import Budget, SearchSpace

    w = _tiny_workload()
    ep = replan(w, MID_RANGE.with_nodes(4), healthy_nodes=3,
                sa_seconds=0.5, sa_iters=60, sa_topk=1, **kw)
    assert ep.plan.feasible
    space_fields = {f.name for f in __import__("dataclasses").fields(
        SearchSpace)}
    for k, v in kw.items():
        dest = (ep.plan.provenance.space if k in space_fields
                else ep.plan.provenance.budget)
        assert getattr(dest, k) == v, k


def test_replan_backend_jax_with_vpp_end_to_end():
    """The acceptance-criteria call: jitted SA backend + interleaved-1F1B
    space through an elastic replan."""
    w = _tiny_workload()
    ep = replan(w, MID_RANGE.with_nodes(4), healthy_nodes=3,
                sa_seconds=1.0, sa_iters=60, sa_topk=1,
                backend="jax", max_vpp=2)
    assert ep.plan.feasible
    assert ep.plan.provenance.budget.backend == "jax"
    assert ep.plan.provenance.space.max_vpp == 2
    assert any(c.conf.vpp > 1 for c in ep.result.ranked)


def test_replan_unknown_kwarg_still_raises():
    w = _tiny_workload()
    with pytest.raises(TypeError, match="unknown replan"):
        replan(w, MID_RANGE.with_nodes(4), healthy_nodes=3,
               sa_seconds=0.05, definitely_not_a_knob=1)


def test_with_nodes_grow_extends_tier_pattern():
    """Satellite (ISSUE 10): the grow path of a tiered spec must cycle
    the tier pattern, not truncate or raise — a joined node inherits the
    tier its slot would have had."""
    from repro.core import MIXED_A100_V100

    spec = MIXED_A100_V100
    pat = spec.node_tiers
    grown = spec.with_nodes(spec.n_nodes + 4)
    assert grown.n_nodes == spec.n_nodes + 4
    assert len(grown.node_tiers) == grown.n_nodes
    reps = -(-grown.n_nodes // len(pat))
    assert grown.node_tiers == (pat * reps)[:grown.n_nodes]
    # and the grow path works end-to-end through replan
    w = _tiny_workload()
    small = spec.with_nodes(2)
    ep = replan(w, small, healthy_nodes=3, sa_seconds=0.5, sa_iters=40,
                sa_topk=1)
    assert ep.n_gpus == 3 * small.gpus_per_node


def test_replan_node_subset_keeps_surviving_tiers():
    """healthy_nodes may be an explicit surviving-node list: "node 1 of 4
    died" keeps nodes 0, 2, 3 *with their own tiers* — unlike the
    count-based truncation."""
    from repro.core import MIXED_A100_V100

    spec = MIXED_A100_V100.with_nodes(4)
    w = _tiny_workload()
    ep = replan(w, spec, healthy_nodes=[0, 2, 3], sa_seconds=0.5,
                sa_iters=40, sa_topk=1)
    assert ep.n_gpus == 3 * spec.gpus_per_node
    tiers = ep.plan.provenance.tiers
    assert tiers is not None
    assert tuple(tiers["node_tiers"]) == tuple(
        spec.node_tiers[i] for i in (0, 2, 3))


def test_partition_and_vpp_do_not_stale_estimator():
    """Satellite (ISSUE 10): partition mode and vpp change which layers a
    stage holds, not the feature layout the memory fit learned — the
    estimator must be kept, not refit."""
    from repro.core import fit_memory_estimator

    w = _tiny_workload()
    spec = MID_RANGE.with_nodes(4)
    est = fit_memory_estimator([w], spec, fit_nodes=2, steps=1500,
                               residual=True)
    ep = replan(w, spec, healthy_nodes=3, estimator=est, sa_seconds=0.5,
                sa_iters=40, sa_topk=1, partition="dp", max_vpp=2)
    assert not ep.refit_estimator
    assert ep.plan.feasible


def test_grown_spec_does_not_stale_estimator():
    """Growing the node count keeps gpu_mem/gpus_per_node, so the fit
    extrapolates over GPU count by design (the same axis a shrink already
    exercised) — no refit on a node join."""
    from repro.core import fit_memory_estimator

    w = _tiny_workload()
    spec = MID_RANGE.with_nodes(2)
    est = fit_memory_estimator([w], spec, fit_nodes=2, steps=1500,
                               residual=True)
    ep = replan(w, spec, healthy_nodes=3, estimator=est, sa_seconds=0.5,
                sa_iters=40, sa_topk=1)
    assert not ep.refit_estimator
    assert ep.n_gpus == 24


def test_incremental_replan_records_lineage_and_migration():
    """An incumbent-seeded replan warm-starts from the projected incumbent
    permutation, records replan lineage, and prices the migration of the
    chosen candidate."""
    w = _tiny_workload()
    spec = MID_RANGE.with_nodes(3)
    first = replan(w, spec, healthy_nodes=3, sa_seconds=0.5, sa_iters=60,
                   sa_topk=1, backend="numpy")
    second = replan(w, spec, healthy_nodes=3, incumbent=first.plan,
                    migration_weight=1e-4, sa_seconds=0.5, sa_iters=60,
                    sa_topk=1, backend="numpy")
    lin = second.plan.provenance.lineage
    assert lin is not None
    assert lin["replan_of"] == first.plan.fingerprint()
    assert lin["warm_start_projected"] is True
    assert lin["survivors"] == 24
    ws = second.plan.provenance.budget.warm_start
    assert ws is not None and sorted(ws) == list(range(24))
    assert second.chosen is not None
    assert second.migration is not None
    assert second.migration.ranks_total == 24


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.ones((4,)) * 5}
    state = opt.init(params)
    for _ in range(120):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(g, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.15


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-3)


def test_powersgd_error_feedback_reduces_error():
    """With error feedback, the accumulated compression bias over repeated
    identical gradients vanishes (the sum of applied updates approaches the
    true gradient direction)."""
    comp = PowerSGD(rank=2, min_compress_size=16)
    key = jax.random.PRNGKey(0)
    g_true = {"w": jax.random.normal(key, (32, 48))}
    errors = comp.init_error(g_true)
    applied = jnp.zeros((32, 48))
    n = 30
    for i in range(n):
        approx, errors = comp.roundtrip(g_true, errors,
                                        jax.random.PRNGKey(i))
        applied = applied + approx["w"]
    rel = float(jnp.linalg.norm(applied / n - g_true["w"]) /
                jnp.linalg.norm(g_true["w"]))
    # one-shot rank-2 of a random 32x48 keeps ~30% energy; with feedback the
    # time-averaged update recovers most of the signal
    one_shot, _ = comp.roundtrip(g_true, comp.init_error(g_true),
                                 jax.random.PRNGKey(99))
    rel_one = float(jnp.linalg.norm(one_shot["w"] - g_true["w"]) /
                    jnp.linalg.norm(g_true["w"]))
    assert rel < rel_one * 0.6


def test_powersgd_compression_ratio():
    comp = PowerSGD(rank=2, min_compress_size=16)
    params = {"w": jnp.zeros((64, 64)), "small": jnp.zeros((3,))}
    assert comp.compression_ratio(params) > 10
