"""Property tests for the 1F1B schedule, simulator structure and the
profile model (hypothesis)."""
import numpy as np
import pytest

from repro.core import MID_RANGE, Conf, Workload, build_profile
from repro.core.simulator import (_one_f_one_b_order, default_mapping,
                                  simulate_iteration)
from repro.models.config import ModelConfig

# optional dep: skip the module without failing collection; assigning the
# names (instead of `from hypothesis import ...` after a statement) keeps
# every real import at the top of the file (ruff E402)
hyp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
given, settings = hyp.given, hyp.settings

GPT = ModelConfig(name="g", family="dense", n_layers=24, d_model=1024,
                  n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=32000)


@settings(max_examples=60, deadline=None)
@given(pp=st.integers(1, 12), s=st.integers(0, 11), n_mb=st.integers(1, 48))
def test_1f1b_order_complete_and_causal(pp, s, n_mb):
    s = min(s, pp - 1)
    ops = _one_f_one_b_order(pp, s, n_mb)
    fwd = [m for op, m in ops if op == "f"]
    bwd = [m for op, m in ops if op == "b"]
    assert fwd == list(range(n_mb))          # every microbatch forward once
    assert bwd == list(range(n_mb))          # and backward once, in order
    # a microbatch's backward never precedes its own forward
    pos = {("f", m): i for i, (op, m) in enumerate(ops) if op == "f"}
    for i, (op, m) in enumerate(ops):
        if op == "b":
            assert i > pos[("f", m)]
    # warmup depth: stage s starts with min(pp - s, n_mb) forwards
    warm = 0
    for op, _ in ops:
        if op != "f":
            break
        warm += 1
    assert warm == min(pp - s, n_mb)


@settings(max_examples=12, deadline=None)
@given(pp=st.sampled_from([1, 2, 4]), tp=st.sampled_from([1, 2, 4]),
       dp=st.sampled_from([1, 2]), mb=st.sampled_from([1, 2, 4]))
def test_simulator_never_deadlocks(pp, tp, dp, mb):
    spec = MID_RANGE.with_nodes(max(1, pp * tp * dp // 8))
    if spec.n_gpus < pp * tp * dp:
        spec = spec.with_nodes(-(-pp * tp * dp // spec.gpus_per_node))
    conf = Conf(pp, tp, dp, mb, 16 * dp * mb)
    w = Workload(GPT, 512, conf.bs_global)
    prof = build_profile(w, spec, conf)
    bw = np.full((spec.n_gpus, spec.n_gpus), 10e9)
    res = simulate_iteration(conf, default_mapping(conf), bw, prof, spec)
    assert res["total"] > 0
    assert np.isfinite(res["total"])


def test_more_microbatches_smaller_bubble_fraction():
    """Iteration time per token improves with more microbatches (bubble
    amortisation) on a uniform cluster."""
    spec = MID_RANGE.with_nodes(4)
    bw = np.full((32, 32), 10e9)
    times = []
    for mb in (8, 4, 2, 1):
        conf = Conf(4, 8, 1, mb, 256)
        w = Workload(GPT, 2048, 256)
        prof = build_profile(w, spec, conf)
        t = simulate_iteration(conf, default_mapping(conf), bw, prof, spec,
                               jitter=0, contention=0)["total"]
        # normalise out the microbatch-efficiency term to isolate the bubble
        eff = mb / (mb + 1.0)
        times.append(t * eff)
    assert times[0] > times[-1] * 0.98


def test_profile_monotonicities():
    spec = MID_RANGE.with_nodes(4)
    w = Workload(GPT, 2048, 256)
    c_tp2 = build_profile(w, spec, Conf(2, 2, 8, 2, 256)).c_fwd
    c_tp8 = build_profile(w, spec, Conf(2, 8, 2, 2, 256)).c_fwd
    assert c_tp8 < c_tp2                      # more TP -> faster microbatch
    m_pp2 = build_profile(w, spec, Conf(2, 4, 4, 2, 256)).msg_dp
    m_pp4 = build_profile(w, spec, Conf(4, 4, 2, 2, 256)).msg_dp
    assert m_pp4 < m_pp2                      # more stages -> smaller shard
