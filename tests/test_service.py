"""Planning-as-a-service: wire fingerprints, the plan cache, batched
search contexts, warm-started annealing, and the async plan server
(cache-hit byte-identity, in-flight coalescing, request batching,
structured admission — the acceptance criteria of the service issue)."""
import contextlib
import dataclasses
import json
import threading

import numpy as np
import pytest

from repro.core import (MID_RANGE, BatchSearchContext, Budget, Plan,
                        Planner, PlanRequest, PipetteStrategy, SearchSpace,
                        Workload, mapping_to_perm, profile_bandwidth,
                        run_search)
from repro.models.config import ModelConfig
from repro.service import (AdmissionError, PlanCache, PlanClient,
                           PlanServer, ServiceError, WireError,
                           decode_plan_request, encode_plan_request,
                           incumbent_perm, request_fingerprint,
                           request_meta, workload_digest)
from repro.service.wire import spec_from_wire, spec_to_wire, workload_from_wire

GPT = ModelConfig(name="g", family="dense", n_layers=16, d_model=1024,
                  n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=32000)
SPEC = MID_RANGE.with_nodes(1)                  # 8 GPUs: fast server tests
W = Workload(GPT, 2048, 32)
BUDGET = Budget(sa_seconds=60.0, sa_iters=40, sa_topk=2)
REQ = PlanRequest(workload=W, spec=SPEC, space=SearchSpace(max_micro=2),
                  budget=BUDGET, seed=7)
#: same workload, different microbatch cap — distance-0 neighbor of REQ
REQ_NEIGHBOR = dataclasses.replace(REQ, space=SearchSpace(max_micro=4))


@pytest.fixture(scope="module")
def bw():
    return profile_bandwidth(SPEC)[0]


@pytest.fixture(scope="module")
def cold_plan(bw):
    return Planner(PipetteStrategy()).plan(REQ, bw)


@contextlib.contextmanager
def running_server(**kw):
    server = PlanServer(port=0, **kw)
    thread = server.start_in_thread()
    try:
        yield server, PlanClient(port=server.port)
    finally:
        server.stop()
        thread.join(timeout=30)
        assert not thread.is_alive(), "plan server failed to shut down"


class CountingEstimator:
    """Duck-typed MemoryEstimator stub: deterministic per-conf rows,
    counts how many batched forwards were issued."""
    with_cp = True
    residual = False
    soft_margin = 1.05
    workload_seq = 2048
    fit_gpu_mem = 80.0
    fit_gpus_per_node = 8

    def __init__(self):
        self.batch_calls = 0

    def predict_batch(self, cfg, confs):
        self.batch_calls += 1
        return np.asarray([float(c.pp + c.tp) for c in confs])


# ---------------------------------------------------------------------------
# wire format + fingerprints
# ---------------------------------------------------------------------------

def test_wire_round_trip_preserves_the_typed_request():
    obj = encode_plan_request(REQ, strategy="exhaustive", day=3)
    req, strategy, day = decode_plan_request(obj)
    assert (strategy, day) == ("exhaustive", 3)
    assert req.workload == REQ.workload
    assert req.spec == REQ.spec
    assert req.space == REQ.space
    assert req.budget == REQ.budget
    assert req.seed == REQ.seed


def test_fingerprint_is_stable_and_covers_the_determinism_domain():
    fp = request_fingerprint(REQ, "pipette", 0)
    assert fp == request_fingerprint(REQ, "pipette", 0)
    variants = [
        request_fingerprint(REQ, "pipette", 1),
        request_fingerprint(REQ, "exhaustive", 0),
        request_fingerprint(dataclasses.replace(REQ, seed=8), "pipette", 0),
        request_fingerprint(REQ_NEIGHBOR, "pipette", 0),
        request_fingerprint(
            dataclasses.replace(REQ, budget=dataclasses.replace(
                BUDGET, sa_iters=41)), "pipette", 0),
        request_fingerprint(
            dataclasses.replace(REQ, budget=dataclasses.replace(
                BUDGET, warm_start=tuple(range(SPEC.n_gpus)))),
            "pipette", 0),
    ]
    assert len({fp, *variants}) == len(variants) + 1


def test_workload_digest_same_for_name_and_inline_config():
    from repro import configs
    by_name = workload_from_wire(
        {"config": "qwen2-7b", "seq": 128, "bs_global": 8})
    inline = workload_from_wire(
        {"config": dataclasses.asdict(configs.get("qwen2-7b")),
         "seq": 128, "bs_global": 8})
    assert workload_digest(by_name) == workload_digest(inline)


def test_spec_wire_round_trip_and_preset_decoding():
    assert spec_from_wire(spec_to_wire(SPEC)) == SPEC
    preset = spec_from_wire({"preset": "mid-range", "nodes": 1})
    assert preset == SPEC
    with pytest.raises(WireError, match="unknown cluster preset"):
        spec_from_wire({"preset": "not-a-fleet"})


def test_decode_errors_are_typed():
    good = encode_plan_request(REQ)
    with pytest.raises(WireError, match="unknown strategy"):
        decode_plan_request({**good, "strategy": "nope"})
    bad_spec = {**good, "cluster": {**good["cluster"], "n_nodes": 0}}
    with pytest.raises(AdmissionError, match="n_nodes"):
        decode_plan_request(bad_spec)


def test_incumbent_perm_extracts_a_gpu_permutation(cold_plan):
    perm = incumbent_perm(json.loads(cold_plan.to_json()))
    assert perm is not None and perm.shape == (SPEC.n_gpus,)
    assert np.array_equal(np.sort(perm), np.arange(SPEC.n_gpus))
    assert np.array_equal(perm, mapping_to_perm(cold_plan.mapping))
    assert incumbent_perm({"best": None}) is None
    assert incumbent_perm({"best": {"mapping": {"oops": 1}}}) is None


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def _meta(fp, seq=2048, cluster="c", strategy="pipette", day=0,
          feasible=True):
    return {"fingerprint": fp, "cluster_digest": cluster,
            "strategy": strategy, "day": day, "seq": seq, "bs_global": 32,
            "d_model": 1024, "n_layers": 16, "feasible": feasible}


def test_cache_hits_return_the_exact_bytes_and_lru_evicts():
    cache = PlanCache(max_entries=2)
    cache.put("a", _meta("a"), '{"plan": "a"}\n')
    cache.put("b", _meta("b"), '{"plan": "b"}\n')
    assert cache.get("a") == '{"plan": "a"}\n'
    cache.put("c", _meta("c"), '{"plan": "c"}\n')   # evicts b (LRU)
    assert cache.get("b") is None
    assert cache.get("a") == '{"plan": "a"}\n'
    assert cache.counters["lru_evictions"] == 1
    assert cache.stats()["memory_entries"] == 2


def test_cache_persists_to_disk_and_survives_a_restart(tmp_path):
    first = PlanCache(tmp_path / "plans")
    first.put("a" * 64, _meta("a" * 64), '{"plan": 1}\n')
    reborn = PlanCache(tmp_path / "plans")
    assert reborn.get("a" * 64) == '{"plan": 1}\n'
    assert reborn.stats()["disk_entries"] == 1
    assert reborn.evict("a" * 64) is True
    assert reborn.get("a" * 64) is None
    assert not list((tmp_path / "plans").glob("*.json"))


def test_cache_drops_corrupt_disk_entries(tmp_path):
    cache = PlanCache(tmp_path / "plans")
    cache.put("a" * 64, _meta("a" * 64), '{"plan": 1}\n')
    (tmp_path / "plans" / (("a" * 64) + ".plan.json")).write_text("{oops")
    reborn = PlanCache(tmp_path / "plans")
    assert reborn.get("a" * 64) is None
    assert reborn.counters["corrupt_dropped"] == 1
    # both the entry and its sidecar are gone, not served
    assert not list((tmp_path / "plans").glob("*.json"))


def test_cache_nearest_neighbor_lookup_is_scoped_and_deterministic():
    cache = PlanCache()
    cache.put("same", _meta("same", seq=2048), "{}")
    cache.put("far", _meta("far", seq=4096), "{}")
    cache.put("alien", _meta("alien", seq=2048, cluster="other"), "{}")
    cache.put("oom", _meta("oom", seq=2048, feasible=False), "{}")
    cache.put("later", _meta("later", seq=2048, day=1), "{}")
    query = _meta("query", seq=2048)

    fp, dist = cache.nearest(query, exclude="query")
    assert (fp, dist) == ("same", 0.0)
    fp, dist = cache.nearest(query, exclude="same")
    assert fp == "far" and dist == pytest.approx(np.log(2.0))
    assert cache.nearest(query, exclude="same", max_distance=0.5) is None
    # ties break lexicographically by fingerprint
    cache.put("also-same", _meta("also-same", seq=2048), "{}")
    fp, _ = cache.nearest(query, exclude="query")
    assert fp == "also-same"


def test_cache_nearest_accepts_previous_day_across_midnight():
    """Regression (ISSUE 10): warm-start eligibility used to require the
    exact same day, so a replan at 00:01 rejected an incumbent cached at
    23:59.  The previous day is now accepted (interconnect drift is
    gradual and the seed only sets a starting point); anything older — or
    from the future — is still rejected, and same-day neighbors win ties
    over previous-day ones."""
    cache = PlanCache()
    cache.put("yesterday", _meta("yesterday", day=6), "{}")
    cache.put("two-days-old", _meta("two-days-old", day=5), "{}")
    cache.put("tomorrow", _meta("tomorrow", day=8), "{}")
    query = _meta("query", day=7)
    fp, dist = cache.nearest(query, exclude="query")
    assert (fp, dist) == ("yesterday", 0.0)
    # a same-day neighbor at equal distance beats the previous-day one,
    # even when the previous-day fingerprint sorts first
    cache.put("z-today", _meta("z-today", day=7), "{}")
    fp, _ = cache.nearest(query, exclude="query")
    assert fp == "z-today"
    # with only stale/future entries there is no warm-start source
    lonely = PlanCache()
    lonely.put("two-days-old", _meta("two-days-old", day=5), "{}")
    lonely.put("tomorrow", _meta("tomorrow", day=8), "{}")
    assert lonely.nearest(query, exclude="query") is None


# ---------------------------------------------------------------------------
# batched search contexts (N requests, one enumerate/predict_batch pass)
# ---------------------------------------------------------------------------

def test_batch_context_is_bit_identical_to_standalone_searches(bw):
    reqs = [REQ, dataclasses.replace(REQ_NEIGHBOR, seed=11)]
    mem_limit = 4.2                     # prunes high pp+tp rows of the stub

    est_batch = CountingEstimator()
    ctx = BatchSearchContext.for_requests(reqs, bw, estimator=est_batch,
                                          mem_limit=mem_limit)
    est_solo = CountingEstimator()
    for req in reqs:
        batched = Plan.from_search(ctx.search(req), req, bw,
                                   strategy="pipette", estimator=est_batch)
        solo = Planner(PipetteStrategy(
            estimator=est_solo, mem_limit=mem_limit)).plan(req, bw)
        assert batched.to_json() == solo.to_json()
    # the whole group shared ONE jitted predict_batch forward
    assert ctx.n_predict_batches == 1
    assert est_batch.batch_calls == 1
    assert est_solo.batch_calls == len(reqs)


def test_batch_context_rejects_incompatible_requests(bw):
    ctx = BatchSearchContext.for_requests([REQ], bw)
    other_workload = dataclasses.replace(
        REQ, workload=Workload(GPT, 4096, 32))
    with pytest.raises(ValueError, match="workload/cluster"):
        ctx.search(other_workload)
    with pytest.raises(ValueError, match="exceeds the"):
        ctx.search(REQ_NEIGHBOR)        # max_micro=4 over the union cap 2
    with pytest.raises(ValueError, match="shape knobs"):
        BatchSearchContext.for_requests(
            [REQ, dataclasses.replace(REQ, space=SearchSpace(
                max_micro=2, max_cp=2))], bw)


# ---------------------------------------------------------------------------
# warm-started annealing
# ---------------------------------------------------------------------------

def test_budget_warm_start_must_be_a_permutation():
    with pytest.raises(ValueError, match="permutation"):
        Budget(warm_start=(0, 2))
    assert Budget(warm_start=[1, 0]).warm_start == (1, 0)


def test_run_search_rejects_a_wrong_sized_warm_start(bw):
    bad = dataclasses.replace(
        REQ, budget=dataclasses.replace(BUDGET, warm_start=(1, 0)))
    with pytest.raises(ValueError, match="warm_start"):
        run_search(bad, bw)


@pytest.mark.parametrize("backend", [None, "numpy"])
def test_warm_start_is_never_worse_and_spends_fewer_accepted_moves(backend):
    """The acceptance gate: seeded from a cached neighbor's incumbent, SA
    reaches a plan at least as good as the cold search's while accepting
    strictly fewer improving moves (or landing on the identical best)."""
    spec = MID_RANGE.with_nodes(2)      # heterogeneous enough that SA works
    bw2 = profile_bandwidth(spec)[0]
    seed_req = PlanRequest(
        workload=W, spec=spec, space=SearchSpace(max_micro=2),
        budget=Budget(sa_seconds=60.0, sa_iters=80, sa_topk=2,
                      backend=backend), seed=7)
    incumbent = run_search(seed_req, bw2)
    perm = tuple(int(x) for x in mapping_to_perm(incumbent.best.mapping))

    neighbor = dataclasses.replace(seed_req, space=SearchSpace(max_micro=4))
    cold = run_search(neighbor, bw2)
    warm = run_search(dataclasses.replace(
        neighbor, budget=dataclasses.replace(
            neighbor.budget, warm_start=perm)), bw2)

    assert warm.best.latency <= cold.best.latency
    same_best = (warm.best.conf == cold.best.conf
                 and np.array_equal(warm.best.mapping, cold.best.mapping))
    assert (warm.overhead.sa_accepted_to_best
            < cold.overhead.sa_accepted_to_best) or same_best
    if backend is None:
        # pinned: the gate is non-vacuous for the legacy engine — cold SA
        # does improve on its init here, the warm incumbent needs no moves
        assert cold.overhead.sa_accepted_to_best > 0
        assert warm.overhead.sa_accepted_to_best == 0


def test_warm_started_plan_records_the_budget_and_lineage(bw, cold_plan):
    perm = tuple(int(x) for x in mapping_to_perm(cold_plan.mapping))
    warm_req = dataclasses.replace(
        REQ_NEIGHBOR, budget=dataclasses.replace(BUDGET, warm_start=perm))
    lineage = {"warm_start_from": "f" * 64, "distance": 0.0}
    plan = Planner(PipetteStrategy()).plan(warm_req, bw, lineage=lineage)
    d = plan.to_json_dict()
    assert d["provenance"]["budget"]["warm_start"] == list(perm)
    assert d["provenance"]["lineage"] == lineage
    # and it round-trips
    assert Plan.from_json_dict(d).provenance.lineage == lineage


# ---------------------------------------------------------------------------
# the plan server
# ---------------------------------------------------------------------------

def test_server_cache_hit_is_byte_identical_and_runs_no_search():
    with running_server(warm_start=False) as (server, client):
        assert client.ping() is True
        first = client.submit(REQ)
        again = client.submit(REQ)
    assert first["meta"]["cache"] == "miss"
    assert again["meta"]["cache"] == "hit"
    assert again["plan"] == first["plan"]
    assert first["meta"]["fingerprint"] == request_meta(
        REQ, "pipette", 0)["fingerprint"]
    # the Overhead proof: exactly one search ever ran
    assert server.counters["searches_run"] == 1
    assert server.counters["cache_hits"] == 1
    assert server.counters["requests"] == 2


def test_server_coalesces_identical_concurrent_requests(cold_plan):
    release, started, calls = threading.Event(), threading.Event(), []

    def plan_fn(req, strategy, day, lineage):
        calls.append((strategy, day))
        started.set()
        assert release.wait(timeout=30)
        return cold_plan

    with running_server(plan_fn=plan_fn, warm_start=False) as \
            (server, client):
        results = []
        worker = threading.Thread(
            target=lambda: results.extend(client.submit_many([REQ] * 3)))
        worker.start()
        assert started.wait(timeout=30)
        # all three are in the house and two of them are waiting on the
        # first one's in-flight future — no second search was started
        stats = PlanClient(port=server.port).stats()
        assert stats["coalesced"] == 2
        assert stats["searches_run"] == 1
        release.set()
        worker.join(timeout=60)
        assert not worker.is_alive()

    assert len(calls) == 1
    assert [r["meta"]["cache"] for r in results] == \
        ["miss", "coalesced", "coalesced"]
    assert len({r["plan"] for r in results}) == 1


def test_server_batches_near_identical_requests_through_one_context(bw):
    est = CountingEstimator()
    with running_server(batch_window=0.5, estimator=est,
                        warm_start=False) as (server, client):
        first, second = client.submit_many([REQ, REQ_NEIGHBOR])
        stats = client.stats()

    assert [r["meta"]["cache"] for r in (first, second)] == ["miss", "miss"]
    assert stats["batch_groups"] == 1
    assert stats["batched_members"] == 2
    assert stats["searches_run"] == 2
    # ONE predict_batch forward served both members ...
    assert stats["predict_batches"] == 1
    assert est.batch_calls == 1
    # ... and each member's plan is byte-identical to its standalone search
    solo_est = CountingEstimator()
    for req, resp in ((REQ, first), (REQ_NEIGHBOR, second)):
        solo = Planner(PipetteStrategy(
            estimator=solo_est, mem_limit=SPEC.mem_floor)).plan(req, bw)
        assert resp["plan"] == solo.to_json()


def test_server_warm_starts_from_the_nearest_cached_neighbor():
    with running_server() as (server, client):
        seeded = client.submit(REQ)
        warmed = client.submit(REQ_NEIGHBOR)
        entries = client.cache_ls()
        stats = client.stats()

    seed_fp = seeded["meta"]["fingerprint"]
    assert warmed["meta"]["cache"] == "miss"
    assert warmed["meta"]["warm_start_from"] == seed_fp
    assert stats["warm_starts"] == 1

    plan = json.loads(warmed["plan"])
    assert plan["provenance"]["lineage"] == \
        {"warm_start_from": seed_fp, "distance": 0.0}
    perm = plan["provenance"]["budget"]["warm_start"]
    assert sorted(perm) == list(range(SPEC.n_gpus))
    by_fp = {e["fingerprint"]: e for e in entries}
    assert by_fp[seed_fp]["warm_started"] is False
    assert by_fp[warmed["meta"]["fingerprint"]]["warm_started"] is True


def test_server_rejects_an_invalid_cluster_with_a_structured_error():
    with running_server(warm_start=False) as (server, client):
        good = encode_plan_request(REQ)
        bad = {**good, "cluster": {**good["cluster"], "n_nodes": 0}}
        resp = client.request(bad)
        with pytest.raises(ServiceError, match="unknown strategy") as ei:
            client._checked(client.request({**good, "strategy": "nope"}))
    assert resp["ok"] is False
    assert resp["error"]["code"] == "admission"
    assert "n_nodes" in resp["error"]["message"]
    assert ei.value.code == "bad-request"
    assert server.counters["admission_rejects"] == 1
    assert server.counters["bad_requests"] == 1
    assert server.counters["searches_run"] == 0


def test_server_evicts_bad_cache_entries_and_recomputes(cold_plan):
    with running_server(warm_start=False) as (server, client):
        first = client.submit(REQ)
        fp = first["meta"]["fingerprint"]
        # poison the entry: valid JSON, but not a servable plan — the
        # admission verifier must catch it and fall back to a cold search
        server.cache.put(fp, _meta(fp), json.dumps({"version": 1}) + "\n")
        again = client.submit(REQ)
        assert client.cache_evict(fp) is True
        third = client.submit(REQ)

    assert again["meta"]["cache"] == "miss"
    assert again["plan"] == first["plan"]
    assert server.counters["cache_invalid"] == 1
    # evict -> cold search again; byte-identical by determinism
    assert third["meta"]["cache"] == "miss"
    assert third["plan"] == first["plan"]
    assert server.counters["searches_run"] == 3


def test_server_persistent_cache_survives_restart_and_corruption(tmp_path):
    cache_dir = tmp_path / "plans"
    with running_server(cache_dir=cache_dir, warm_start=False) as \
            (server, client):
        first = client.submit(REQ)
        fp = first["meta"]["fingerprint"]

    # a fresh server on the same directory serves from disk, no search
    with running_server(cache_dir=cache_dir, warm_start=False) as \
            (server2, client2):
        again = client2.submit(REQ)
        assert again["meta"]["cache"] == "hit"
        assert again["plan"] == first["plan"]
        assert server2.counters["searches_run"] == 0

    # corrupt the artifact on disk: dropped, recomputed cold, identical
    (cache_dir / f"{fp}.plan.json").write_text("{oops")
    with running_server(cache_dir=cache_dir, warm_start=False) as \
            (server3, client3):
        recomputed = client3.submit(REQ)
        assert recomputed["meta"]["cache"] == "miss"
        assert recomputed["plan"] == first["plan"]
        assert server3.counters["searches_run"] == 1
        assert server3.cache.counters["corrupt_dropped"] == 1


# ---------------------------------------------------------------------------
# CLI plumbing
# ---------------------------------------------------------------------------

def test_cli_parser_covers_the_service_surface():
    from repro.service.__main__ import build_parser
    parser = build_parser()
    serve = parser.parse_args(["serve", "--port-file", "p", "--batch-window",
                               "0.1"])
    assert serve.batch_window == 0.1
    submit = parser.parse_args(
        ["submit", "--port", "1", "--config", "qwen2-7b", "--reduced",
         "--cluster", "mid-range", "--nodes", "1", "--strategy",
         "exhaustive"])
    assert (submit.config, submit.strategy) == ("qwen2-7b", "exhaustive")
    evict = parser.parse_args(["cache", "evict", "f" * 64, "--port", "1"])
    assert evict.fingerprint == "f" * 64
