"""Sharding-policy invariants: every parameter spec the policy emits must
divide the tensor on both production meshes, for every assigned arch —
this is the property the 80-cell dry-run depends on."""
import os
from types import SimpleNamespace

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.models import model as M
from repro.models.sharding import ShardCtx, tree_pspecs

# Shape-only checks (jax.eval_shape), but force a multi-device host platform
# anyway so the file also runs on single-device CPU runners the way
# test_multidevice does for its subprocesses.  `import jax` does not
# initialise the backend — XLA_FLAGS is read lazily on first device use —
# so setting it at module (collection) time, after the imports, is early
# enough and keeps the imports at the top of the file (ruff E402).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def _flatten_with_path(tree):
    """``jax.tree.flatten_with_path`` where available (jax >= 0.5), else the
    ``jax.tree_util`` spelling (jax 0.4.x)."""
    if hasattr(jax.tree, "flatten_with_path"):
        return jax.tree.flatten_with_path(tree)
    return jax.tree_util.tree_flatten_with_path(tree)

MESHES = {
    "16x16": {"data": 16, "model": 16},
    "2x16x16": {"pod": 2, "data": 16, "model": 16},
}


def _fake_ctx(mesh_name):
    shape = MESHES[mesh_name]
    mesh = SimpleNamespace(shape=shape)
    dp = ("pod", "data") if "pod" in shape else ("data",)
    return ShardCtx(mesh=mesh, dp=dp, tp="model", fsdp=("data",))


@pytest.mark.parametrize("arch", sorted(configs.ARCHS))
@pytest.mark.parametrize("mesh_name", list(MESHES))
def test_param_specs_divide(arch, mesh_name):
    cfg = configs.get(arch)
    ctx = _fake_ctx(mesh_name)
    sds = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    specs = tree_pspecs(sds, cfg, ctx)

    def check(path, leaf_sds, spec):
        assert len(spec) <= len(leaf_sds.shape), (path, spec)
        for dim, ax in zip(leaf_sds.shape, tuple(spec) + (None,) * 9):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            n = 1
            for a in axes:
                n *= MESHES[mesh_name][a]
            assert dim % n == 0, (arch, mesh_name, path, dim, ax)

    flat_s, _ = _flatten_with_path(sds)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for (path, leaf), spec in zip(flat_s, flat_p):
        check(jax.tree_util.keystr(path), leaf, spec)


@pytest.mark.parametrize("arch", ["kimi-k2-1t-a32b", "command-r-plus-104b",
                                  "qwen2-7b"])
def test_tp_actually_shards_big_weights(arch):
    """The model axis must land on at least the FFN/expert weights —
    otherwise TP is a no-op and the dry-run memory numbers lie."""
    cfg = configs.get(arch)
    ctx = _fake_ctx("16x16")
    sds = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    specs = tree_pspecs(sds, cfg, ctx)
    layer_specs = specs["layers"]
    key = "e_gate" if cfg.family == "moe" else "gate"
    spec = layer_specs[key]
    axes = {a for ax in spec if ax is not None
            for a in ((ax,) if isinstance(ax, str) else ax)}
    assert "model" in axes, (arch, spec)
