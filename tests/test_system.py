"""End-to-end system tests: tiny-model training convergence, microbatch
accumulation equivalence, input-spec constructibility for every assignment
cell, and the full Pipette->train integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import DataLoader, LoaderConfig, SyntheticCorpus
from repro.launch.steps import make_decode_step, make_train_step
from repro.models import model as M
from repro.models.config import SHAPES, ModelConfig
from repro.models.sharding import ShardCtx
from repro.optim.adamw import AdamW

CTX = ShardCtx()


def test_tiny_training_loss_decreases():
    """A tiny dense model must learn the synthetic Markov stream."""
    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=64, dtype="float32", remat=False)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, CTX, opt, n_micro=2),
                   donate_argnums=(0, 1))
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0, noise=0.02)
    loader = DataLoader(corpus, LoaderConfig(8, 32))
    losses = []
    for s in range(60):
        params, opt_state, m = step(params, opt_state, loader.batch_at(s))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:5]) - 0.5, \
        (losses[:5], losses[-10:])


def test_microbatch_accumulation_equivalence():
    """n_micro=1 vs n_micro=4 accumulate to (numerically) the same update."""
    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                      vocab_size=32, dtype="float32", remat=False)
    opt = AdamW(lr=1e-3, weight_decay=0.0)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=1)
    batch = DataLoader(corpus, LoaderConfig(8, 16)).batch_at(0)

    outs = []
    for n_micro in (1, 4):
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        state = opt.init(params)
        step = jax.jit(make_train_step(cfg, CTX, opt, n_micro=n_micro))
        p2, _, m = step(params, state, batch)
        outs.append((p2, float(m["loss"])))
    assert outs[0][1] == pytest.approx(outs[1][1], rel=1e-4)
    for a, b in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[1][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-5)


@pytest.mark.parametrize("arch", sorted(configs.ARCHS))
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_constructible(arch, shape):
    """Every assignment cell's inputs must be constructible as
    ShapeDtypeStructs (mesh-less here; the dry-run attaches shardings)."""
    from repro.launch import specs as SP
    cfg = configs.get(arch)
    ss = SHAPES[shape]
    if ss.name == "long_500k" and not cfg.is_subquadratic:
        pytest.skip("documented skip: full-attention arch at 500k")
    if ss.kind in ("train", "prefill"):
        b = SP.batch_spec(cfg, ss, CTX)
        assert b["tokens"].shape[0] == ss.global_batch
        if cfg.frontend == "vlm":
            assert b["tokens"].shape[1] + cfg.n_img_tokens == ss.seq_len
        else:
            assert b["tokens"].shape[1] == ss.seq_len
    else:
        token, cache, pos = SP.decode_inputs(cfg, ss, CTX)
        assert token.shape == (ss.global_batch, 1)
        assert cache, "decode arch must have a cache"
        for k, v in cache.items():
            if k in ("k", "v"):
                assert v.shape[2] == ss.seq_len
            if k == "k_ring":
                assert v.shape[2] == 1024      # gemma3 local window


def test_serve_decode_runs_greedy():
    cfg = configs.get("musicgen-large").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size, jnp.int32)
    last, cache = M.prefill(params, cfg, CTX, toks)
    cache = {k: (jnp.pad(v, [(0, 0), (0, 0), (0, 4)] + [(0, 0)] * (v.ndim - 3))
                 if k in ("k", "v") else v) for k, v in cache.items()}
    step = jax.jit(make_decode_step(cfg, CTX), donate_argnums=(1,))
    tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    for i in range(3):
        tok, logits, cache = step(params, cache, tok, jnp.int32(16 + i))
        assert tok.shape == (2, 1)
        assert bool(jnp.isfinite(logits).all())


def test_configure_then_train_integration(tmp_path):
    """Pipette picks a config on the simulated cluster; training consumes
    its bs_micro as the accumulation length."""
    from repro.core import MID_RANGE, Workload, configure, profile_bandwidth
    cfg = configs.get("qwen2-7b").reduced()
    spec = MID_RANGE.with_nodes(2)
    w = Workload(cfg, 64, 64)
    bw, _ = profile_bandwidth(spec)
    res = configure(w, spec, bw, sa_seconds=0.05, sa_iters=400)
    assert res.best is not None
    n_micro = max(1, min(4, res.best.conf.n_mb))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, CTX, opt, n_micro=n_micro))
    loader = DataLoader(SyntheticCorpus(cfg.vocab_size, 0),
                        LoaderConfig(8, 64))
    p2, _, m = step(params, opt.init(params), loader.batch_at(0))
    assert np.isfinite(float(m["loss"]))
