"""Vectorized dedication engine: bit-exact equivalence against the
pure-Python reference scorer, incremental delta-scoring correctness, and
multi-start determinism."""
import numpy as np
import pytest

from repro.core import (MID_RANGE, Conf, Workload, anneal, anneal_multistart,
                        build_profile, dp_allreduce_times,
                        dp_allreduce_times_ref, pipette_latency,
                        pipette_latency_ref, true_bandwidth_matrix)
from repro.core.dedication import DedicationEngine, GroupIndex, _move_span, \
    perm_to_mapping
from repro.models.config import ModelConfig

GPT = ModelConfig(name="g", family="dense", n_layers=24, d_model=1920,
                  n_heads=20, n_kv_heads=20, d_ff=7680, vocab_size=51200)


def _random_case(rng, trial):
    """One random (spec, conf, bw, prof, mapping) triple."""
    spec = MID_RANGE.with_nodes(int(rng.choice([1, 2, 4, 8])))
    g = spec.n_gpus
    shapes = [(pp, tp, g // (pp * tp))
              for pp in (1, 2, 4) for tp in (1, 2, 4, 8)
              if g % (pp * tp) == 0]
    pp, tp, dp = shapes[rng.integers(len(shapes))]
    conf = Conf(pp, tp, dp, 2, 16 * dp)
    bw = true_bandwidth_matrix(spec, day=trial % 4)
    prof = build_profile(Workload(GPT, 512, conf.bs_global), spec, conf)
    mapping = perm_to_mapping(rng.permutation(g), conf)
    return spec, conf, bw, prof, mapping


def test_vectorized_latency_matches_reference_exactly():
    """>= 50 random (cluster, conf, mapping) triples, tolerance 0."""
    rng = np.random.default_rng(0)
    for trial in range(60):
        spec, conf, bw, prof, mapping = _random_case(rng, trial)
        vec = pipette_latency(conf, mapping, bw, prof, spec)
        ref = pipette_latency_ref(conf, mapping, bw, prof, spec)
        assert vec == ref, (trial, str(conf), vec - ref)


def test_vectorized_dp_allreduce_matches_reference_exactly():
    rng = np.random.default_rng(1)
    for trial in range(50):
        spec, conf, bw, prof, mapping = _random_case(rng, trial)
        vec = dp_allreduce_times(conf, mapping, bw, prof, spec)
        ref = dp_allreduce_times_ref(conf, mapping, bw, prof, spec)
        assert np.array_equal(vec, ref), (trial, str(conf))


def test_engine_full_score_matches_latency():
    rng = np.random.default_rng(2)
    for trial in range(20):
        spec, conf, bw, prof, _ = _random_case(rng, trial)
        eng = DedicationEngine(conf, bw, prof, spec)
        perm = rng.permutation(conf.n_gpus)
        want = pipette_latency(conf, perm_to_mapping(perm, conf), bw, prof,
                               spec)
        assert eng.score(perm) == want


def test_engine_delta_scoring_matches_full_rescore():
    """Every SA move's incremental score equals a from-scratch evaluation."""
    rng = np.random.default_rng(3)
    for trial in range(10):
        spec, conf, bw, prof, _ = _random_case(rng, trial)
        eng = DedicationEngine(conf, bw, prof, spec)
        perm = rng.permutation(conf.n_gpus)
        eng.score(perm)
        for _ in range(50):
            cand, touched = _move_span(perm, rng)
            val, pending = eng.propose(cand, touched)
            want = pipette_latency(conf, perm_to_mapping(cand, conf), bw,
                                   prof, spec)
            assert val == want, (trial, str(conf), val - want)
            if rng.random() < 0.6:          # mix accepted + rejected moves
                eng.commit(pending)
                perm = cand


def test_group_index_shared_across_microbatch_variants():
    conf_a = Conf(2, 4, 2, 1, 32)
    conf_b = Conf(2, 4, 2, 4, 32)
    idx = GroupIndex.build(conf_a)
    spec = MID_RANGE.with_nodes(2)
    bw = true_bandwidth_matrix(spec)
    for conf in (conf_a, conf_b):
        prof = build_profile(Workload(GPT, 512, conf.bs_global), spec, conf)
        eng = DedicationEngine(conf, bw, prof, spec, index=idx)
        perm = np.random.default_rng(0).permutation(conf.n_gpus)
        want = pipette_latency(conf, perm_to_mapping(perm, conf), bw, prof,
                               spec)
        assert eng.score(perm) == want
    with pytest.raises(ValueError):
        DedicationEngine(Conf(4, 2, 2, 1, 32), bw,
                         build_profile(Workload(GPT, 512, 32), spec,
                                       Conf(4, 2, 2, 1, 32)),
                         spec, index=idx)


def test_engine_anneal_matches_generic_objective_path():
    """The engine-driven anneal walks the exact same trajectory as the
    generic (full-rescore) objective path: same RNG stream + bit-equal
    scores => identical accept/reject decisions."""
    spec = MID_RANGE.with_nodes(4)
    conf = Conf(4, 4, 2, 2, 128)
    bw = true_bandwidth_matrix(spec)
    prof = build_profile(Workload(GPT, 2048, 128), spec, conf)

    def objective(p):
        return pipette_latency_ref(conf, perm_to_mapping(p, conf), bw, prof,
                                   spec)

    kw = dict(time_limit_s=60.0, max_iters=800, seed=11)
    r_eng = anneal(conf, bw, prof, spec, **kw)
    r_gen = anneal(conf, bw, prof, spec, objective=objective, **kw)
    assert r_eng.latency == r_gen.latency
    assert np.array_equal(r_eng.perm, r_gen.perm)


def test_multistart_deterministic_and_no_worse_than_single():
    spec = MID_RANGE.with_nodes(4)
    conf = Conf(4, 4, 2, 2, 128)
    bw = true_bandwidth_matrix(spec)
    prof = build_profile(Workload(GPT, 2048, 128), spec, conf)
    kw = dict(n_chains=3, time_limit_s=60.0, max_iters=900, seed=5)
    a = anneal_multistart(conf, bw, prof, spec, **kw)
    b = anneal_multistart(conf, bw, prof, spec, **kw)
    assert a.latency == b.latency
    assert np.array_equal(a.perm, b.perm)
    assert a.chain_latencies == b.chain_latencies
    assert len(a.chain_latencies) == 3
    assert a.latency == min(a.chain_latencies)
    # the winning chain is at least as good as chain 0 alone
    single = anneal(conf, bw, prof, spec, time_limit_s=60.0, max_iters=300,
                    seed=5 * 100003)
    assert a.latency <= single.latency
    with pytest.raises(ValueError):
        anneal_multistart(conf, bw, prof, spec, n_chains=0)
